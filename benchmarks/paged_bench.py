"""Paged KV cache vs dense rings -> BENCH_paged.json.

Three measurements, sized for the 1-core CPU dev box:

  * **Capacity** -- max concurrent rows inside a fixed KV arena byte
    budget.  The dense ring reserves ``total_len + 1`` token slots per
    row up front; the paged allocator hands out ``page_size``-token
    blocks on demand and maps radix-shared prompt-prefix blocks into
    sibling rows instead of duplicating them, so the same bytes hold
    strictly more rows whenever prompts are long or shared
    (``n_per_prompt`` siblings per prompt, the paper's GRPO shape).
    The gate is ``capacity_ratio_ge_2x``.

  * **Admission cost with/without a radix hit** -- compiled-model FLOPs
    (``cost_analysis``) and wall latency of ``admit_row_paged`` at
    ``n_cached=0`` (fresh prefill) vs a radix hit covering every full
    prompt block.  A hit prefills only the un-cached suffix, skipping
    the prefix's attention/FFN work entirely; the gate is
    ``radix_flops_skip_ge_90``.

  * **Decode parity** -- tokens/s of ``rollout_rows_chunk`` over
    matched dense/paged pools, plus a bitwise comparison of the decoded
    tokens and logits (``paged_equals_dense``): page-table indirection
    reorders memory, never math, and must not cost decode throughput.
"""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timeit
from repro.configs.llama_paper import smoke
from repro.models import init_params
from repro.models.paging import (PagePool, RadixCache, paged_blocks,
                                 plan_admission)
from repro.rl.rollout import (admit_row, admit_row_paged,
                              rollout_rows_chunk, start_rollout,
                              start_row_pool)

# capacity sim: long shared prompts, short generations -- the regime the
# paper's n_per_prompt sibling groups put the generator in
CAP_PAGE = 8
CAP_PROMPT = 56
CAP_TOTAL = 64
CAP_SIBS = 4
CAP_PAGES = 64                       # fixed arena: 64 * 8 = 512 KV slots

# admission cost: one long prompt, all full blocks radix-cached on a hit
ADM_PAGE = 4
ADM_PROMPT = 88
ADM_TOTAL = 96


def micro_cfg(vocab=64):
    return smoke().replace(n_layers=1, d_model=32, n_heads=2, n_kv_heads=2,
                           head_dim=16, d_ff=64, vocab=vocab)


def measure_capacity() -> dict:
    """Admit sibling groups until the fixed arena backpressures; the
    dense ring's capacity is the same byte budget divided by its fixed
    per-row reservation."""
    mb = paged_blocks(CAP_TOTAL, CAP_PAGE)
    pool = PagePool(CAP_PAGES)
    radix = RadixCache(pool, CAP_PAGE)
    rng = np.random.RandomState(0)
    paged_rows = 0
    while True:
        prompt = tuple(int(t) for t in rng.randint(1, 64, CAP_PROMPT))
        admitted = 0
        for _ in range(CAP_SIBS):
            plan = plan_admission(pool, radix, prompt, mb, CAP_PAGE)
            if plan is None:
                break
            radix.insert(prompt, plan.table)
            admitted += 1
        paged_rows += admitted
        if admitted < CAP_SIBS:
            break
    arena_tokens = CAP_PAGES * CAP_PAGE
    dense_rows = arena_tokens // (CAP_TOTAL + 1)
    return {
        "arena_kv_token_slots": arena_tokens,
        "page_size": CAP_PAGE,
        "prompt_len": CAP_PROMPT,
        "total_len": CAP_TOTAL,
        "n_per_prompt": CAP_SIBS,
        "dense_max_rows": dense_rows,
        "paged_max_rows": paged_rows,
        "capacity_ratio": paged_rows / max(dense_rows, 1),
    }


def _flops(fn, *args, **static) -> float:
    jitted = jax.jit(fn, static_argnames=tuple(static))
    ca = jitted.lower(*args, **static).compile().cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    return float(ca.get("flops", 0.0))


def measure_admission() -> dict:
    cfg = micro_cfg()
    params = init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    mb = paged_blocks(ADM_TOTAL, ADM_PAGE)
    n_cached = (ADM_PROMPT - 1) // ADM_PAGE * ADM_PAGE
    pool = start_row_pool(cfg, 2, ADM_TOTAL, ADM_PROMPT, kv_layout="paged",
                          kv_page_size=ADM_PAGE, kv_pages=2 * mb)
    trash = 2 * mb
    prompt = jnp.asarray(np.random.RandomState(1).randint(
        1, cfg.vocab, (1, ADM_PROMPT)), jnp.int32)
    pages = jnp.asarray(list(range(mb)) + [trash], jnp.int32)

    def admit(state, n):
        return admit_row_paged(params, cfg, state, prompt, pages, 0,
                               n_cached=n)

    flops_miss = _flops(lambda s: admit(s, 0), pool)
    flops_hit = _flops(lambda s: admit(s, n_cached), pool)
    lat_miss = timeit(lambda: jax.block_until_ready(admit(pool, 0)))
    lat_hit = timeit(lambda: jax.block_until_ready(admit(pool, n_cached)))
    return {
        "prompt_len": ADM_PROMPT,
        "page_size": ADM_PAGE,
        "n_cached_on_hit": n_cached,
        "prefill_flops_miss": flops_miss,
        "prefill_flops_hit": flops_hit,
        "flops_skip_frac": 1.0 - flops_hit / max(flops_miss, 1.0),
        "admit_latency_miss_s": lat_miss,
        "admit_latency_hit_s": lat_hit,
    }


def measure_decode() -> dict:
    cfg = micro_cfg()
    params = init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    R, T, Sp, P = 4, 15, 6, 4            # mb * P = 16 = T + 1: parity
    dense = start_row_pool(cfg, R, T, Sp)
    paged = start_row_pool(cfg, R, T, Sp, kv_layout="paged", kv_page_size=P)
    mb = paged_blocks(T, P)
    alloc = PagePool(R * mb)
    rng = np.random.RandomState(2)
    for slot in range(R):
        pr = jnp.asarray(rng.randint(1, cfg.vocab, (1, Sp)), jnp.int32)
        row = start_rollout(params, cfg, pr, T, cache_len=T + 1)
        dense = admit_row(dense, row, slot)
        plan = plan_admission(alloc, None, tuple(int(t) for t in pr[0]),
                              mb, P)
        paged = admit_row_paged(
            params, cfg, paged, pr,
            jnp.asarray(plan.table + (alloc.trash_page,), jnp.int32),
            slot, n_cached=0)
    key = jax.random.PRNGKey(9)
    n_steps = 8
    t_dense = timeit(lambda: rollout_rows_chunk(params, cfg, dense, key,
                                                n_steps=n_steps))
    t_paged = timeit(lambda: rollout_rows_chunk(params, cfg, paged, key,
                                                n_steps=n_steps))
    d = rollout_rows_chunk(params, cfg, dense, key, n_steps=n_steps)
    p = rollout_rows_chunk(params, cfg, paged, key, n_steps=n_steps)
    equal = bool(
        (np.asarray(d.tokens) == np.asarray(p.tokens)).all()
        and (np.asarray(d.last_logits) == np.asarray(p.last_logits)).all())
    return {
        "rows": R,
        "n_steps": n_steps,
        "dense_tokens_per_s": R * n_steps / t_dense,
        "paged_tokens_per_s": R * n_steps / t_paged,
        "paged_over_dense": t_dense / t_paged,
        "paged_equals_dense": equal,
    }


def main() -> None:
    report = {
        "capacity": measure_capacity(),
        "admission": measure_admission(),
        "decode": measure_decode(),
    }
    report["capacity_ratio_ge_2x"] = \
        report["capacity"]["capacity_ratio"] >= 2.0
    report["radix_flops_skip_ge_90"] = \
        report["admission"]["flops_skip_frac"] >= 0.90
    report["paged_equals_dense"] = report["decode"]["paged_equals_dense"]
    out = os.environ.get("REPRO_PAGED_JSON", "BENCH_paged.json")
    with open(out, "w") as f:
        json.dump(report, f, indent=2)
    cap = report["capacity"]
    emit("paged_capacity", 0.0,
         f"dense={cap['dense_max_rows']};paged={cap['paged_max_rows']};"
         f"ratio={cap['capacity_ratio']:.2f}")
    adm = report["admission"]
    emit("paged_admit_miss", adm["admit_latency_miss_s"] * 1e6,
         f"flops={adm['prefill_flops_miss']:.0f}")
    emit("paged_admit_hit", adm["admit_latency_hit_s"] * 1e6,
         f"flops={adm['prefill_flops_hit']:.0f};"
         f"skip={adm['flops_skip_frac']:.3f}")
    dec = report["decode"]
    emit("paged_decode", 0.0,
         f"dense_tok_s={dec['dense_tokens_per_s']:.1f};"
         f"paged_tok_s={dec['paged_tokens_per_s']:.1f};"
         f"speed_ratio={dec['paged_over_dense']:.2f}")
    for gate in ("capacity_ratio_ge_2x", "radix_flops_skip_ge_90",
                 "paged_equals_dense"):
        emit(f"paged_{gate}", 0.0, str(report[gate]))
    emit("paged_json", 0.0, out)


if __name__ == "__main__":
    main()
