"""Generate EXPERIMENTS.md dry-run/roofline tables from the JSON records."""
from __future__ import annotations

import glob
import json
import os

from repro import configs
from repro.configs.base import INPUT_SHAPES, param_count


def load(pattern):
    out = {}
    for f in sorted(glob.glob(pattern)):
        r = json.load(open(f))
        out[(r["arch"], r["shape"], r.get("mesh_name", "pod1"),
             os.path.basename(f))] = r
    return out


def useful(rec):
    cfg = configs.get_config(rec["arch"])
    shape = INPUT_SHAPES[rec["shape"]]
    _, active = param_count(cfg)
    tokens = shape.global_batch * (1 if shape.kind == "decode"
                                   else shape.seq_len)
    mult = 6 if shape.kind == "train" else 2
    return mult * active * tokens / max(
        rec["flops_per_device"] * rec["n_chips"], 1.0)


def roofline_table():
    rows = ["| arch | shape | compute_s | memory_s | collective_s | "
            "dominant | useful | peak GB/chip | fits 16GB |",
            "|---|---|---|---|---|---|---|---|---|"]
    for (arch, shape, mesh, _), r in sorted(
            load("experiments/dryrun/*_pod1.json").items()):
        t = r["roofline"]
        rows.append(
            f"| {arch} | {shape} | {t['compute_s']:.3f} | "
            f"{t['memory_s']:.3f} | {t['collective_s']:.3f} | "
            f"{r['dominant'][:-2]} | {useful(r):.2f} | "
            f"{r['peak_bytes_per_device']/1e9:.1f} | "
            f"{'yes' if r['fits_hbm'] else 'NO'} |")
    return "\n".join(rows)


def dryrun_table():
    rows = ["| arch | shape | mesh | chips | compile_s | "
            "args GB/chip | temps GB/chip | collectives |",
            "|---|---|---|---|---|---|---|---|"]
    for mesh in ("pod1", "pod2"):
        for (arch, shape, m, _), r in sorted(
                load(f"experiments/dryrun/*_{mesh}.json").items()):
            cols = ",".join(f"{k.split('-')[1] if '-' in k else k}:"
                            f"{v/1e9:.0f}G"
                            for k, v in sorted(r["collectives"].items(),
                                               key=lambda kv: -kv[1])[:3])
            rows.append(
                f"| {arch} | {shape} | {m} | {r['n_chips']} | "
                f"{r['compile_s']} | {r['argument_bytes']/1e9:.2f} | "
                f"{r['temp_bytes']/1e9:.1f} | {cols} |")
    return "\n".join(rows)


def inject(md_path, marker, table):
    s = open(md_path).read()
    s = s.replace(f"<!-- {marker} -->", table)
    open(md_path, "w").write(s)


if __name__ == "__main__":
    inject("EXPERIMENTS.md", "ROOFLINE_TABLE", roofline_table())
    inject("EXPERIMENTS.md", "DRYRUN_TABLE", dryrun_table())
    print("tables injected")
