"""Paper Table 3: RL step-time, synchronous baseline vs LlamaRL async.

Two parts:
  (a) MEASURED at CPU dev-box scale: wall-clock per RL step for the sync
      (Fig. 2a) vs async (Fig. 2b) controller on the same tiny model --
      the async win comes from overlapping generation with training.
  (b) ANALYTIC at paper scale: Section-7 solvers with eta curves calibrated
      so the synchronous baseline matches Table 3's measured step times
      (22.45 / 82.32 / 635.8 s), then the async optimum is *predicted* and
      compared against the paper's measured LlamaRL rows.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import build_pipeline, emit, tiny_cfg
from repro.core.theory import EtaCurve, llama_hw, solve_async, solve_sync

PAPER_ROWS = [
    # size_B, gpus, T_sync (paper), best T_async (paper)
    (8, 256, 22.45, 8.90),
    (70, 256, 82.32, 20.67),
    (405, 1024, 635.8, 59.5),
]


def measured_cpu_scale(steps=6):
    cfg = tiny_cfg()
    out = {}
    for mode in ("sync", "async"):
        # one compile-only step first, then time the steady state: in async
        # mode generation overlaps training, so per-step *wall clock* (not
        # the consumer thread's busy time) is the honest comparison
        ctl = build_pipeline(cfg, mode=mode, max_steps=1, lr=1e-3)
        ctl.run()
        ctl.max_steps = steps
        t0 = time.perf_counter()
        ctl.run()
        out[mode] = (time.perf_counter() - t0) / steps
    return out


def analytic_paper_scale():
    rows = []
    for size, gpus, t_sync_paper, t_async_paper in PAPER_ROWS:
        hw = llama_hw(size, gpus)
        # calibrate eta curves: alpha from paper sync time, mild 1/b term
        base = t_sync_paper * gpus / (hw.B0 * 5 * (4 * hw.W0 + hw.W0)
                                      / hw.M0) / 2
        eta_t = EtaCurve(alpha=base, beta=base * 16)
        eta_g = EtaCurve(alpha=base * 3, beta=base * 64)
        s = solve_sync(hw, eta_t, eta_g)
        a = solve_async(hw, eta_t, eta_g)
        scale = t_sync_paper / s["T"]          # calibrate to paper sync row
        rows.append({
            "size": size,
            "T_sync": s["T"] * scale,
            "T_async_pred": a["T"] * scale,
            "speedup_pred": s["T"] / a["T"],
            "speedup_paper": t_sync_paper / t_async_paper,
        })
    return rows


def main():
    m = measured_cpu_scale()
    emit("table3/measured_sync_step", m["sync"] * 1e6)
    emit("table3/measured_async_step", m["async"] * 1e6,
         f"speedup={m['sync'] / m['async']:.2f}x;"
         "note=async is the threaded controller: generator and trainer "
         "run on concurrent threads, so overlap is real wall-clock "
         "(bounded by host cores; paper-scale wins need disjoint device "
         "groups, analytic rows + Thm 7.5)")
    for r in analytic_paper_scale():
        emit(f"table3/analytic_{r['size']}B_sync", r["T_sync"] * 1e6)
        emit(f"table3/analytic_{r['size']}B_async", r["T_async_pred"] * 1e6,
             f"pred={r['speedup_pred']:.2f}x;paper={r['speedup_paper']:.2f}x")


if __name__ == "__main__":
    main()
