"""Paper Fig. 8: off-policy corrections stabilize asynchronous training.

Ablation under deep staleness (3 steps) + int8-quantized generator (both
off-policyness sources from the paper): AIPO one-sided clip vs PPO clip vs
NO correction.  Stability metric: max |mean IS ratio - 1| and the gradient-
norm spikiness across steps (the paper's 'sudden drops' manifest as ratio /
grad blowups at this scale)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import build_pipeline, emit, tiny_cfg

STEPS = 18


def run(clip_mode, seed=0):
    cfg = tiny_cfg(d_model=96, d_ff=192)
    ctl = build_pipeline(cfg, mode="async", staleness=3,
                         clip_mode=clip_mode, lr=2e-2, n_prompts=8,
                         n_per_prompt=4, max_new=5, max_steps=STEPS,
                         seed=seed, quantize=True, max_operand=4)
    hist = ctl.run()
    ratios = np.array([h["mean_ratio"] for h in hist[2:]])
    gnorms = np.array([h["grad_norm"] for h in hist[2:]])
    clip = np.array([h.get("clip_frac", 0.0) for h in hist[2:]])
    return {
        "ratio_dev": float(np.max(np.abs(ratios - 1.0))),
        "grad_p95": float(np.percentile(gnorms, 95)),
        "grad_med": float(np.median(gnorms)),
        "clip_frac": float(np.mean(clip)),
        "reward": float(np.mean([h.get("mean_reward", 0) for h in hist[-6:]])),
        "max_staleness": max(ctl.staleness_hist),
        "staleness_hist": dict(sorted(ctl.staleness_hist.items())),
        "queue_depth": float(np.mean([h["queue_depth"] for h in hist])),
        "overlap_s": ctl.stats.get("overlap_s", 0.0),
    }


def main():
    res = {m: run(m) for m in ("aipo", "ppo", "is_unclipped", "none")}
    for m, r in res.items():
        emit(f"fig8/{m}_grad_p95", r["grad_p95"] * 1e6,
             f"ratio_dev={r['ratio_dev']:.3f};clip={r['clip_frac']:.3f};"
             f"reward={r['reward']:.3f}")
    emit("fig8/stability", 0.0,
         f"aipo_grad_p95={res['aipo']['grad_p95']:.3f};"
         f"unclipped={res['is_unclipped']['grad_p95']:.3f};"
         f"corrections_stabilize="
         f"{res['aipo']['grad_p95'] <= res['is_unclipped']['grad_p95']}")
    r = res["aipo"]
    emit("fig8/offpolicyness", r["max_staleness"] * 1e6,
         f"staleness_hist={r['staleness_hist']};"
         f"mean_queue_depth={r['queue_depth']:.2f};"
         f"gen_train_overlap_s={r['overlap_s']:.2f}")


if __name__ == "__main__":
    main()
