# One function per paper table/figure. Prints ``name,us_per_call,derived``
# CSV rows.  Keep everything tiny: 1-core CPU dev box.
import importlib
import sys
import traceback

MODULES = [
    "benchmarks.table3_step_time",   # Table 3: sync vs async step time
    "benchmarks.table4_weight_sync", # Table 4: DDMA vs parameter-server
    "benchmarks.fig5_batch_scaling", # Fig 5: Assumption 7.1
    "benchmarks.fig6_quality",       # Fig 6: quality parity
    "benchmarks.fig7_scaling",       # Fig 7: speedup vs scale
    "benchmarks.fig8_offpolicy",     # Fig 8: off-policy corrections
    "benchmarks.thm75_check",        # Theorem 7.5 numeric check
    "benchmarks.roofline",           # deliverable (g) report
    "benchmarks.kernels_bench",      # naive vs streamed -> BENCH_kernels.json
    "benchmarks.genpool_bench",      # generator pool -> BENCH_genpool.json
]


def main() -> None:
    print("name,us_per_call,derived")
    failures = 0
    for mod in MODULES:
        try:
            importlib.import_module(mod).main()
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"{mod},0.0,ERROR:{type(e).__name__}:{e}")
            traceback.print_exc(file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == '__main__':
    main()
