# One entry point for every benchmark.  Prints ``name,us_per_call,
# derived`` CSV rows; modules that write BENCH_*.json artifacts do so as
# a side effect.  Keep everything tiny: 2-core CPU dev box.
#
# Discovery is automatic: every module in benchmarks/ that defines a
# ``main()`` is a producer and runs -- a new bench file is registered by
# existing, so no BENCH_*.json producer can fall out of this entry
# point.  ``_ORDER`` pins the paper-table ordering for the report;
# newly-discovered modules append alphabetically after it.
import importlib
import pkgutil
import sys
import traceback

import benchmarks

_HELPERS = {"run", "common", "make_report"}   # no main() / not producers

_ORDER = [
    "table3_step_time",   # Table 3: sync vs async step time
    "table4_weight_sync", # Table 4: DDMA vs parameter-server
    "fig5_batch_scaling", # Fig 5: Assumption 7.1
    "fig6_quality",       # Fig 6: quality parity
    "fig7_scaling",       # Fig 7: speedup vs scale
    "fig8_offpolicy",     # Fig 8: off-policy corrections
    "thm75_check",        # Theorem 7.5 numeric check
    "roofline",           # deliverable (g) report
    "kernels_bench",      # naive vs streamed -> BENCH_kernels.json
    "genpool_bench",      # generator pool -> BENCH_genpool.json
    "transport_bench",    # thread vs process actors -> BENCH_transport.json
]


def discover():
    found = sorted(m.name for m in pkgutil.iter_modules(benchmarks.__path__)
                   if m.name not in _HELPERS)
    ordered = [m for m in _ORDER if m in found]
    return ordered + [m for m in found if m not in _ORDER]


def main() -> None:
    print("name,us_per_call,derived")
    failures = 0
    for name in discover():
        try:
            mod = importlib.import_module(f"benchmarks.{name}")
            if not hasattr(mod, "main"):
                failures += 1
                print(f"benchmarks.{name},0.0,ERROR:no main() entry point")
                continue
            mod.main()
        except Exception as e:  # noqa: BLE001 - isolate per producer
            failures += 1
            print(f"benchmarks.{name},0.0,ERROR:{type(e).__name__}:{e}")
            traceback.print_exc(file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == '__main__':
    main()
