"""Paper Fig. 7: efficiency gain grows with model scale.

Section-7 solvers with the paper's actual async advantages modeled: the
async framework decouples trainer/generator parallelism AND lets the
generator run quantized (fp8 -> W0/2 in the generator memory constraint,
paper Sec. 4.3 / Table 3's best rows).  At scale, weights dominate memory,
so the quantization+decoupling dividend grows -- reproducing the paper's
rising speedup trend."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.core.theory import EtaCurve, llama_hw, solve_sync


def _mp_penalty(m):
    """Per-sample-time inflation for model-parallel degrees beyond one node
    (paper Sec. 4.3: 'smaller mp (especially when mp > 8) ... significantly
    reduce the inter-node communications')."""
    import math
    return 1.0 + 0.15 * max(0.0, math.log2(max(m, 1) / 8))


def sync_with_mp_penalty(hw, eta_t, eta_g):
    grid = [2 ** i for i in range(15)]
    best = None
    for b_t in grid:
        for b_g in grid:
            m = ((4 * hw.W0 + hw.A_t * b_t)
                 + (hw.W0 + hw.K_g * b_g)) / hw.M0
            if m > hw.G0:
                continue
            t = hw.B0 / hw.G0 * m * _mp_penalty(m) * \
                (eta_t(b_t) + eta_g(b_g))
            best = t if best is None else min(best, t)
    return best


def async_with_quantized_generator(hw, eta_t, eta_g):
    """solve_async variant: generator weights at W0/2 (fp8), mp penalty."""
    grid = [2 ** i for i in range(15)]
    Tt, Tg = None, None
    for b_t in grid:
        m_t = (4 * hw.W0 + hw.A_t * b_t) / hw.M0
        v = eta_t(b_t) * m_t * _mp_penalty(m_t)
        Tt = v if Tt is None else min(Tt, v)
    for b_g in grid:
        m_g = (hw.W0 / 2 + hw.K_g * b_g) / hw.M0
        v = eta_g(b_g) * m_g * _mp_penalty(m_g)
        Tg = v if Tg is None else min(Tg, v)
    theta = Tt / (Tt + Tg)
    return hw.B0 / hw.G0 * max(Tt / theta, Tg / (1 - theta))


def main():
    gains = []
    for size, gpus in [(8, 256), (70, 256), (405, 1024)]:
        hw = llama_hw(size, gpus)
        eta_t = EtaCurve(alpha=2e-3 * size / 8, beta=5e-2 * size / 8)
        eta_g = EtaCurve(alpha=8e-3 * size / 8, beta=3e-1 * size / 8)
        t_sync = sync_with_mp_penalty(hw, eta_t, eta_g)
        t_async = async_with_quantized_generator(hw, eta_t, eta_g)
        sp = t_sync / t_async
        gains.append((size, sp))
        emit(f"fig7/speedup_{size}B", sp * 1e6,
             "sync(shared-mp,bf16) vs async(decoupled-mp,fp8 generator)")
    xs = np.log([g[0] for g in gains])
    ys = [g[1] for g in gains]
    slope1 = (ys[1] - ys[0]) / (xs[1] - xs[0])
    slope2 = (ys[2] - ys[1]) / (xs[2] - xs[1])
    emit("fig7/growth_trend", 0.0,
         f"speedups={[round(y, 2) for y in ys]};"
         f"slopes={slope1:.3f}->{slope2:.3f};increasing={slope2 >= slope1}")


if __name__ == "__main__":
    main()
