"""Paper Fig. 5: per-sample processing time decreases with batch size
(Assumption 7.1) -- measured for both training steps and generation on the
tiny model, then fitted to eta(b) = alpha + beta/b."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timeit, tiny_cfg
from repro.core.theory import fit_eta
from repro.rl.rollout import generate
from repro.train.trainstep import init_train_state, make_train_step


def main():
    cfg = tiny_cfg()
    state = init_train_state(cfg, jax.random.PRNGKey(0), jnp.float32)
    step = jax.jit(make_train_step(cfg, lr=1e-3))
    S = 24
    etas_t, etas_g, bs = [], [], [4, 8, 16, 32]
    for b in bs:
        batch = {
            "tokens": jnp.ones((b, S), jnp.int32),
            "behavior_logp": jnp.zeros((b, S)),
            "advantages": jnp.ones((b, S)),
            "mask": jnp.ones((b, S)),
        }
        t = timeit(lambda: step(state, batch)[1]["loss"])
        etas_t.append(t / b)
        emit(f"fig5/train_eta_b{b}", t / b * 1e6)
    params = state.params
    for b in bs:
        prompts = jnp.ones((b, 8), jnp.int32) * 5
        t = timeit(lambda: generate(params, cfg, prompts, max_new=8,
                                    key=jax.random.PRNGKey(1)).tokens)
        etas_g.append(t / b)
        emit(f"fig5/gen_eta_b{b}", t / b * 1e6)
    mono_t = all(etas_t[i + 1] <= etas_t[i] * 1.05 for i in range(3))
    mono_g = all(etas_g[i + 1] <= etas_g[i] * 1.05 for i in range(3))
    ct = fit_eta(bs, etas_t)
    cg = fit_eta(bs, etas_g)
    emit("fig5/assumption_7_1", 0.0,
         f"train_monotone={mono_t};gen_monotone={mono_g};"
         f"eta_t=({ct.alpha:.2e}+{ct.beta:.2e}/b);"
         f"eta_g=({cg.alpha:.2e}+{cg.beta:.2e}/b)")


if __name__ == "__main__":
    main()
