"""Fault injection and recovery -> BENCH_faults.json.

Measures what supervised recovery (ISSUE 7) actually costs on the
process-backed pool:

  * ``clean``   -- pool-of-2 proc run, no faults (the baseline);
  * ``faulted`` -- the same run with one scripted SIGKILL
    (``kill:generator1@batch=3``): time-to-recovery (backoff + respawn +
    weight replay, from the supervisor's ``respawned`` event), the
    throughput dip vs the clean run, and trainer idle;
  * ``degraded_4_to_3`` -- runtime shrink on the inproc pool: detach one
    of four workers mid-run and compare samples/sec against the intact
    pool-of-4.

The dip bound is generous: a respawned child pays a fresh interpreter +
XLA-backend import inside the faulted wall-clock, which dominates these
micro runs in a way it never would at real batch sizes.
"""
import json
import os
import threading
import time

from benchmarks.common import emit
from repro.configs.llama_paper import smoke
from repro.core import (CommType, CommunicationChannel, ExecutorController,
                        FaultPlan, RestartPolicy, RewardExecutor, Supervisor,
                        TrainerExecutor, build_generator_pool,
                        close_all_actors)
from repro.rl.data import ArithmeticTasks

STEPS = 8
DEGRADE_STEPS = 12
STALENESS = 1
N_PROMPTS, N_PER_PROMPT, MAX_NEW, CHUNK = 2, 2, 4, 2
FAULT = "kill:generator1@batch=3"
DIP_BOUND = 8.0                    # respawn pays a whole child cold-start


def micro_cfg():
    return smoke().replace(n_layers=1, d_model=32, n_heads=2, n_kv_heads=2,
                           head_dim=16, d_ff=64, vocab=64)


def build(n_gens=2, transport="proc", chaos=None, max_steps=STEPS):
    cfg = micro_cfg()
    rew = RewardExecutor(n_per_prompt=N_PER_PROMPT)
    trn = TrainerExecutor(cfg, lr=5e-3, seed=0)
    gens, chans = build_generator_pool(
        cfg, trn,
        lambda g: ArithmeticTasks(prompt_len=8, max_operand=9, ops="+",
                                  seed=g),
        n_generators=n_gens, n_prompts=N_PROMPTS,
        n_per_prompt=N_PER_PROMPT, max_new=MAX_NEW, temperature=1.0,
        chunk=CHUNK, transport=transport)
    chans += [CommunicationChannel("completions", gens[0], rew,
                                   CommType.GATHER),
              CommunicationChannel("completions_with_reward", rew, trn,
                                   CommType.SCATTER)]
    return ExecutorController(
        gens + [rew, trn], chans, max_steps=max_steps, mode="async",
        staleness=STALENESS, timeout=600.0,
        supervise=Supervisor(RestartPolicy(), chaos=chaos))


def summarize(ctl, hist, steps) -> dict:
    wall = ctl.stats["wall_s"]
    samples = steps * N_PROMPTS * N_PER_PROMPT
    return {
        "wall_s": wall,
        "train_idle_s": ctl.stats["train_idle_s"],
        "samples_per_s": samples / max(wall, 1e-9),
        "completed_all_batches":
            [h["step"] for h in hist] == list(range(steps)),
        "max_staleness": max(ctl.staleness_hist) if ctl.staleness_hist
            else 0,
    }


def main() -> None:
    clean = build()
    rc = summarize(clean, clean.run(), STEPS)

    chaos = FaultPlan.parse(FAULT)
    faulty = build(chaos=chaos)
    rf = summarize(faulty, faulty.run(), STEPS)
    respawns = faulty.supervisor.events("respawned")
    rf["respawns"] = len(respawns)
    rf["time_to_recovery_s"] = respawns[0]["recovery_s"] if respawns \
        else None

    # runtime shrink 4 -> 3: the degrade path without a corpse, so the
    # comparison isolates remapping cost from child cold-start
    degraded = build(n_gens=4, transport="inproc", max_steps=DEGRADE_STEPS)

    def shrink():
        deadline = time.monotonic() + 120.0
        while len(degraded.history) < 4 and time.monotonic() < deadline:
            time.sleep(0.02)
        degraded.detach_generator("generator3")

    t = threading.Thread(target=shrink)
    t.start()
    rd = summarize(degraded, degraded.run(), DEGRADE_STEPS)
    t.join(timeout=120.0)
    rd["pool_resized"] = [e["n_workers"]
                          for e in degraded.supervisor.events("pool-resized")]
    intact = build(n_gens=4, transport="inproc", max_steps=DEGRADE_STEPS)
    ri = summarize(intact, intact.run(), DEGRADE_STEPS)

    report = {
        "steps": STEPS, "staleness": STALENESS, "fault": FAULT,
        "batch": {"n_prompts": N_PROMPTS, "n_per_prompt": N_PER_PROMPT,
                  "max_new": MAX_NEW, "chunk": CHUNK},
        "clean": rc,
        "faulted": rf,
        "throughput_dip_ratio": rf["wall_s"] / max(rc["wall_s"], 1e-9),
        "degraded_4_to_3": rd,
        "intact_pool4": ri,
    }
    report["recovered"] = bool(respawns) and rf["completed_all_batches"] \
        and rf["max_staleness"] <= STALENESS
    report["bounded_dip"] = report["throughput_dip_ratio"] <= DIP_BOUND
    report["degrade_completed"] = rd["completed_all_batches"] \
        and rd["pool_resized"] == [3]

    out = os.environ.get("REPRO_FAULTS_JSON", "BENCH_faults.json")
    with open(out, "w") as f:
        json.dump(report, f, indent=2)
    emit("faults_clean", rc["wall_s"] * 1e6 / STEPS,
         f"samples_per_s={rc['samples_per_s']:.1f}")
    emit("faults_killed", rf["wall_s"] * 1e6 / STEPS,
         f"recovery_s={rf['time_to_recovery_s']};"
         f"dip={report['throughput_dip_ratio']:.2f}")
    emit("faults_recovered", 0.0, str(report["recovered"]))
    emit("faults_degrade_4_to_3", rd["wall_s"] * 1e6 / DEGRADE_STEPS,
         f"samples_per_s={rd['samples_per_s']:.1f};"
         f"pool4={ri['samples_per_s']:.1f}")
    emit("faults_json", 0.0, out)
    close_all_actors()


if __name__ == "__main__":
    main()
