"""Tracing overhead benchmark -> BENCH_obs.json (ISSUE 8).

Two measurements, matching the tracer's two cost claims:

  * ``noop`` -- per-call cost of the module-level ``span()`` /
    ``instant()`` helpers with tracing disabled (the zero-cost-when-off
    claim: one global load and a shared no-op object, no allocation)
    and enabled (ring-buffer append), in nanoseconds.
  * ``pipeline`` -- the same small async RL pipeline run untraced and
    traced (all the real seams instrumented: controller phases, pool
    workers, scheduler chunks, fabric publishes), wall-clock from
    ``controller.stats``.  The acceptance bar: traced wall within 5%
    of untraced (``overhead_frac < 0.05``).

A jit-warmup run precedes both timed runs so neither pays first-compile
cost; runs alternate from the same process and configuration.
"""
import json
import time

from benchmarks.common import build_pipeline, emit, tiny_cfg
from repro.core import close_all_actors
from repro.obs import trace as obs_trace

STEPS = 10
MICRO_N = 200_000


def bench_noop() -> dict:
    obs_trace.disable()
    span = obs_trace.span
    instant = obs_trace.instant
    t0 = time.perf_counter()
    for _ in range(MICRO_N):
        with span("x", "bench"):
            pass
    disabled_span_ns = (time.perf_counter() - t0) / MICRO_N * 1e9
    t0 = time.perf_counter()
    for _ in range(MICRO_N):
        instant("x", "bench")
    disabled_instant_ns = (time.perf_counter() - t0) / MICRO_N * 1e9
    obs_trace.enable("bench", capacity=1 << 14)
    t0 = time.perf_counter()
    for _ in range(MICRO_N):
        with span("x", "bench"):
            pass
    enabled_span_ns = (time.perf_counter() - t0) / MICRO_N * 1e9
    obs_trace.disable()
    return {"disabled_span_ns": disabled_span_ns,
            "disabled_instant_ns": disabled_instant_ns,
            "enabled_span_ns": enabled_span_ns}


def _run_pipeline() -> dict:
    ctl = build_pipeline(tiny_cfg(), mode="async", staleness=1,
                         max_steps=STEPS)
    try:
        ctl.run()
        return dict(ctl.stats)
    finally:
        close_all_actors()


def bench_pipeline() -> dict:
    obs_trace.disable()
    _run_pipeline()                      # jit warmup (discarded)
    untraced = traced = None
    n_events = 0
    for _ in range(2):                   # alternate: min damps scheduler
        w = _run_pipeline()["wall_s"]    # noise and residual-compile skew
        untraced = w if untraced is None else min(untraced, w)
        t = obs_trace.enable("controller")
        t.clear()
        try:
            w = _run_pipeline()["wall_s"]
            n_events = max(n_events, len(t.events()))
        finally:
            obs_trace.disable()
        traced = w if traced is None else min(traced, w)
    overhead = traced / untraced - 1.0
    return {"steps": STEPS, "untraced_wall_s": untraced,
            "traced_wall_s": traced,
            "overhead_frac": overhead, "trace_events": n_events}


def main():
    results = {"noop": bench_noop(), "pipeline": bench_pipeline()}
    emit("obs/noop_span_disabled", results["noop"]["disabled_span_ns"] / 1e3,
         f"ns={results['noop']['disabled_span_ns']:.0f}")
    emit("obs/noop_span_enabled", results["noop"]["enabled_span_ns"] / 1e3,
         f"ns={results['noop']['enabled_span_ns']:.0f}")
    p = results["pipeline"]
    emit("obs/pipeline_traced", p["traced_wall_s"] * 1e6,
         f"overhead={p['overhead_frac']:+.1%},events={p['trace_events']}")
    with open("BENCH_obs.json", "w") as f:
        json.dump(results, f, indent=1)


if __name__ == "__main__":
    main()
