"""Weight-sync fabric benchmark -> BENCH_fabric.json.

Three measurements, matching the fabric's three claims (ISSUE 5 /
paper Sec. 5.2):

  * ``payload`` -- one-way weight-publication throughput of each remote
    transport for a weights-sized pytree: ``proc`` (every byte copied
    through an OS pipe), ``shm`` (bytes scattered once into a
    shared-memory ring slot, header over the pipe), ``socket``
    (localhost TCP).  The acceptance bar: shm bytes/s strictly above
    the proc pipe path.
  * ``scatter`` -- ``wire.serialize`` (flatten + join allocation) vs
    ``wire.plan`` + ``serialize_into`` a preallocated buffer (the shm
    write path): the serialization toll with and without staging
    copies.
  * ``overlap`` -- the end-to-end async pipeline over ``shm`` with the
    fabric's background publisher vs the blocking consumer fan-out:
    publish wall-clock, the fraction hidden behind generation
    (``publish_overlap_s / publish_s``), and trainer/generator idle
    under each.  The acceptance bar: a nonzero overlap fraction for
    the fabric.
"""
import json
import os
import time

import numpy as np

from benchmarks.common import build_pipeline, emit, tiny_cfg
from repro.core import Executor, close_all_actors, spawn_actor
from repro.core import wire

PAYLOAD_MB = 16
CASTS = 6
REPEATS = 3


def weights_tree(mb: int):
    rng = np.random.default_rng(0)
    n = mb * (1 << 20) // 4 // 8
    return {f"layer{i}": {"w": rng.standard_normal(n).astype(np.float32)}
            for i in range(8)}


def bench_payload(transport: str, tree, mb: float) -> dict:
    """One-way publication throughput: N ``stage_weights`` casts (the
    fabric's data-plane write) closed by a call barrier."""
    h = spawn_actor(Executor, f"sink-{transport}", transport=transport)
    try:
        # warm both directions (spawn, first attach/grow of shm slots)
        h.cast("stage_weights", tree, 0)
        h.call("staged_versions")
        best = None
        for _ in range(REPEATS):
            t0 = time.perf_counter()
            for _ in range(CASTS):
                h.cast("stage_weights", tree, 0)   # overwrites one slot
            h.call("staged_versions")              # barrier: all applied
            dt = time.perf_counter() - t0
            best = dt if best is None else min(best, dt)
        return {"payload_mb": mb, "casts": CASTS,
                "mb_per_s": mb * CASTS / best, "wall_s": best}
    finally:
        h.close()


def bench_scatter(tree, mb: float) -> dict:
    ser = scat = None
    planned = wire.plan(tree)
    buf = bytearray(planned.size)
    for _ in range(REPEATS):
        t0 = time.perf_counter()
        blob = wire.serialize(tree)
        ser = min(ser or 1e9, time.perf_counter() - t0)
        t0 = time.perf_counter()
        wire.serialize_into(wire.plan(tree), buf)
        scat = min(scat or 1e9, time.perf_counter() - t0)
    assert bytes(buf) == blob, "scatter layout must match serialize"
    return {"payload_mb": mb, "serialize_mb_s": mb / ser,
            "scatter_into_mb_s": mb / scat}


def bench_overlap(overlap: bool) -> dict:
    os.environ.setdefault("REPRO_SHM_THRESHOLD", str(1 << 12))
    ctl = build_pipeline(tiny_cfg(n_layers=1, d_model=64, d_ff=128,
                                  n_heads=2, n_kv_heads=2, head_dim=32),
                         mode="async", staleness=2, max_steps=2,
                         n_prompts=4, n_per_prompt=2, max_new=6,
                         transport="shm")
    ctl.overlap_publish = overlap
    ctl._fabric.overlap = overlap
    try:
        ctl.run()                        # warm the jit caches / children
        ctl.max_steps = 8
        ctl.run()                        # measured continuation
        s = dict(ctl.stats)
        s["publish_overlap_frac"] = (s["publish_overlap_s"] /
                                     max(s["publish_s"], 1e-9))
        return {k: round(v, 4) for k, v in s.items()}
    finally:
        close_all_actors()


def main() -> None:
    tree = weights_tree(PAYLOAD_MB)
    mb = sum(leaf["w"].nbytes for leaf in tree.values()) / (1 << 20)
    payload = {t: bench_payload(t, tree, mb)
               for t in ("proc", "shm", "socket")}
    report = {
        "payload": payload,
        "scatter": bench_scatter(tree, mb),
        "overlap": {"fabric": bench_overlap(True),
                    "blocking_fanout": bench_overlap(False)},
        "shm_vs_pipe_speedup":
            payload["shm"]["mb_per_s"] / payload["proc"]["mb_per_s"],
        # the acceptance flags: shm beats the pipe for weight-sized
        # payloads, and the fabric hides publication behind generation
        "shm_beats_pipe":
            bool(payload["shm"]["mb_per_s"] > payload["proc"]["mb_per_s"]),
    }
    report["publish_overlap_nonzero"] = bool(
        report["overlap"]["fabric"]["publish_overlap_frac"] > 0.0)
    out = os.environ.get("REPRO_FABRIC_JSON", "BENCH_fabric.json")
    with open(out, "w") as f:
        json.dump(report, f, indent=2)
    for t, r in payload.items():
        emit(f"fabric_payload_{t}", r["wall_s"] * 1e6 / r["casts"],
             f"{r['mb_per_s']:.0f}MB/s")
    emit("fabric_shm_vs_pipe", 0.0,
         f"speedup={report['shm_vs_pipe_speedup']:.2f}x;"
         f"beats_pipe={report['shm_beats_pipe']}")
    emit("fabric_publish_overlap", 0.0,
         f"fabric={report['overlap']['fabric']['publish_overlap_frac']:.2f};"
         f"blocking="
         f"{report['overlap']['blocking_fanout']['publish_overlap_frac']:.2f}")
    emit("fabric_json", 0.0, out)


if __name__ == "__main__":
    main()
