"""Paper Table 4: weight-synchronization time, DDMA vs parameter-server.

Measured on this box: resharding ``device_put`` (DDMA path, device-to-
device) vs host-staged gather+scatter (the OpenRLHF-style slow path), over
growing model sizes.  Derived column projects the DDMA path to paper scale
(405B bf16 over ICI at 50 GB/s/link, fully distributed => time ~ shard
bytes / link bw, the linear-scaling claim behind Table 4's 2.31 s).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from benchmarks.common import emit, timeit
from repro.core import ddma
from repro.launch.mesh import make_dev_mesh


def params_of_size(n_floats: int, key=0):
    n = max(n_floats // 4, 1)
    ks = jax.random.split(jax.random.PRNGKey(key), 4)
    return {f"w{i}": jax.random.normal(ks[i], (n,), jnp.float32)
            for i in range(4)}


def main():
    mesh = make_dev_mesh()
    sh = NamedSharding(mesh, P())
    n_dev = len(jax.devices())
    note = ("note=single-device: both paths are host memcpy; the TPU "
            "difference is structural (no host staging)" if n_dev == 1 else
            f"note={n_dev}-device mesh (emulated on CPU under "
            "xla_force_host_platform_device_count): DDMA replicates "
            "device-to-device, PS stages through one host copy")
    for mb in (1, 8, 64):
        params = params_of_size(mb * 1_000_000 // 4)
        t_ddma, _ = ddma.timed_sync(ddma.ddma_weight_sync, params, sh)
        t_ps, _ = ddma.timed_sync(ddma.ps_weight_sync, params, sh)
        emit(f"table4/ddma_{mb}MB", t_ddma * 1e6,
             f"ps={t_ps*1e6:.0f}us;ratio={t_ps/max(t_ddma,1e-9):.1f}x;"
             + note)
    # paper-scale projection: 405B bf16 = 810GB spread over 512 generator
    # chips => ~1.6 GB/chip; at 50 GB/s/link with direct ICI transfers and
    # full parallelism the wire time is ~32 ms; the paper measures 2.31 s
    # end-to-end (layout + rendezvous overheads dominate the wire time).
    shard_gb = 405e9 * 2 / 512 / 1e9
    wire_s = shard_gb / 50.0
    emit("table4/projected_405b_wire", wire_s * 1e6,
         "paper_measured=2.31s;linear_in_shard_bytes")


if __name__ == "__main__":
    main()
