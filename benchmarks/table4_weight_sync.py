"""Paper Table 4: weight-synchronization time, DDMA vs parameter-server.

Run under the CI multi-device smoke job's 8 emulated devices this builds
a *real trainer/generator mesh pair* (two disjoint (1, 4) submeshes,
paper Def. 7.4's theta split): params start sharded across the trainer
submesh, and each sync path moves them onto the generator submesh --
resharding ``device_put`` (the DDMA path, device-to-device) vs
host-staged gather+scatter (the OpenRLHF-style slow path).  On a
single-device box both paths degrade to host memcpy and the run is
labelled as such.  ``timed_sync`` warms up (layout/compilation) and
syncs inputs before t0, so the numbers measure transfer, not tracing.

Emits CSV lines plus ``BENCH_table4.json`` recording the mesh shapes
alongside every timing.  The derived column projects the DDMA path to
paper scale (405B bf16 over ICI at 50 GB/s/link, fully distributed =>
time ~ shard bytes / link bw, the linear-scaling claim behind Table 4's
2.31 s).
"""
from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from benchmarks.common import emit
from repro.core import ddma
from repro.launch.mesh import make_dev_mesh, trainer_generator_submeshes


def params_of_size(n_floats: int, lanes: int, key=0):
    """Four 1-D fp32 leaves, sized to a multiple of ``lanes`` so a
    model-axis sharding divides them evenly."""
    n = max(n_floats // 4 // lanes, 1) * lanes
    ks = jax.random.split(jax.random.PRNGKey(key), 4)
    return {f"w{i}": jax.random.normal(ks[i], (n,), jnp.float32)
            for i in range(4)}


def _mesh_desc(mesh) -> dict:
    return {"shape": [int(mesh.shape[a]) for a in mesh.axis_names],
            "axes": list(mesh.axis_names),
            "n_devices": int(np.prod([mesh.shape[a]
                                      for a in mesh.axis_names]))}


def main():
    n_dev = len(jax.devices())
    report = {"n_devices": n_dev, "sizes_mb": [], "results": {}}
    if n_dev >= 2:
        # the real pair: disjoint trainer/generator submeshes; trainer
        # shards along its model axis, the sync reshards onto the
        # generator's model axis -- every leaf actually changes devices
        t_mesh, g_mesh = trainer_generator_submeshes(0.5)
        src_sh = NamedSharding(t_mesh, P("model"))
        dst_sh = NamedSharding(g_mesh, P("model"))
        lanes = int(t_mesh.shape["model"]) * int(g_mesh.shape["model"])
        report["trainer_mesh"] = _mesh_desc(t_mesh)
        report["generator_mesh"] = _mesh_desc(g_mesh)
        note = (f"trainer_mesh={report['trainer_mesh']['shape']};"
                f"generator_mesh={report['generator_mesh']['shape']};"
                "disjoint submeshes, trainer-sharded -> generator-sharded")
    else:
        mesh = make_dev_mesh()
        src_sh = dst_sh = NamedSharding(mesh, P())
        lanes = 1
        report["trainer_mesh"] = report["generator_mesh"] = _mesh_desc(mesh)
        note = ("single-device: both paths are host memcpy; the TPU "
                "difference is structural (no host staging)")
    for mb in (1, 8, 64):
        params = jax.device_put(params_of_size(mb * 1_000_000 // 4, lanes),
                                src_sh)
        t_ddma, _ = ddma.timed_sync(ddma.ddma_weight_sync, params, dst_sh)
        t_ps, _ = ddma.timed_sync(ddma.ps_weight_sync, params, dst_sh)
        report["sizes_mb"].append(mb)
        report["results"][f"{mb}MB"] = {
            "ddma_s": t_ddma, "ps_s": t_ps,
            "ratio_ps_over_ddma": t_ps / max(t_ddma, 1e-9)}
        emit(f"table4/ddma_{mb}MB", t_ddma * 1e6,
             f"ps={t_ps*1e6:.0f}us;ratio={t_ps/max(t_ddma,1e-9):.1f}x;"
             f"note={note}")
    # paper-scale projection: 405B bf16 = 810GB spread over 512 generator
    # chips => ~1.6 GB/chip; at 50 GB/s/link with direct ICI transfers and
    # full parallelism the wire time is ~32 ms; the paper measures 2.31 s
    # end-to-end (layout + rendezvous overheads dominate the wire time).
    shard_gb = 405e9 * 2 / 512 / 1e9
    wire_s = shard_gb / 50.0
    report["projected_405b_wire_s"] = wire_s
    emit("table4/projected_405b_wire", wire_s * 1e6,
         "paper_measured=2.31s;linear_in_shard_bytes")
    out = os.environ.get("REPRO_TABLE4_JSON", "BENCH_table4.json")
    with open(out, "w") as f:
        json.dump(report, f, indent=2)
    emit("table4/json", 0.0, out)


if __name__ == "__main__":
    main()
